//! Integration tests over the real artifacts (skipped gracefully when
//! `make artifacts` has not run — CI without the AOT step still passes
//! unit tests).

use std::rc::Rc;

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::runtime::Registry;
use shareprefill::serving::{Engine, EngineCore, Event, EventSink, Request,
                            Scheduler};
use shareprefill::workloads::tasks::{latency_prompt, sample, Task};

fn registry() -> Option<Rc<Registry>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(open_registry(&Config::default()).expect("registry"))
}

#[test]
fn golden_vectors_match_compiled_artifacts() {
    let Some(reg) = registry() else { return };
    let report = shareprefill::eval::golden::run_golden(&reg, "sim-llama")
        .expect("golden");
    assert!(report.contains("golden OK"));
}

#[test]
fn shareprefill_prefill_close_to_dense() {
    // The engine's sparse output at γ→1 must track dense logits closely;
    // at the calibrated γ the argmax should usually agree.
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let prompt = latency_prompt(256);
    let mut dense = build_engine(&reg, &cfg, "sim-llama",
                                 MethodKind::Flash).unwrap();
    let pre_d = dense.prefill(&prompt).unwrap();
    let ld = dense.logits_last(&pre_d).unwrap();

    let mut cfg_hi = cfg.clone();
    cfg_hi.method.gamma = 0.99;
    let mut ours = build_engine(&reg, &cfg_hi, "sim-llama",
                                MethodKind::SharePrefill).unwrap();
    let pre_s = ours.prefill(&prompt).unwrap();
    let ls = ours.logits_last(&pre_s).unwrap();

    let d_arg = shareprefill::serving::engine::argmax(&ld);
    let s_arg = shareprefill::serving::engine::argmax(&ls);
    assert_eq!(d_arg, s_arg, "γ=0.99 sparse argmax diverged from dense");
}

#[test]
fn flash_engine_matches_decode_consistency() {
    // decode(1 token) after prefill equals the last-position argmax.
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-llama",
                                  MethodKind::Flash).unwrap();
    let s = sample(Task::EnDia, 3, 256);
    let pre = engine.prefill(&s.prompt).unwrap();
    let logits = engine.logits_last(&pre).unwrap();
    let (gen, _) = engine.decode(&pre, 1).unwrap();
    assert_eq!(gen[0] as usize,
               shareprefill::serving::engine::argmax(&logits));
}

#[test]
fn gqa_model_serves() {
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-qwen",
                                  MethodKind::SharePrefill).unwrap();
    let pre = engine.prefill(&latency_prompt(256)).unwrap();
    assert!(pre.stats.blocks_total > 0);
    let (gen, _) = engine.decode(&pre, 3).unwrap();
    assert_eq!(gen.len(), 3);
}

#[test]
fn scheduler_end_to_end() {
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-llama",
                                  MethodKind::SharePrefill).unwrap();
    let mut sched: Scheduler<Engine> = Scheduler::new(&cfg.serve);
    let (sink, rx) = EventSink::channel();
    for i in 0..3 {
        assert!(sched.submit(Request::new(i, latency_prompt(256), 2),
                             sink.clone()));
    }
    drop(sink);
    let mut done = Vec::new();
    while sched.has_work() {
        done.extend(sched.run_round(&mut engine).unwrap());
    }
    assert_eq!(done.len(), 3);
    assert_eq!(sched.metrics.requests_completed, 3);
    assert_eq!(sched.kv.used(), 0, "all kv blocks released");
    for r in &done {
        assert_eq!(r.generated.len(), 2);
        assert!(r.prefill_us > 0);
        assert!(r.ttft_us > 0);
    }
    let events: Vec<Event> = rx.iter().collect();
    let dones = events.iter()
        .filter(|e| matches!(e, Event::Done { .. }))
        .count();
    let prefill_dones = events.iter()
        .filter(|e| matches!(e, Event::PrefillDone { .. }))
        .count();
    assert_eq!(dones, 3);
    assert_eq!(prefill_dones, 3);
}

#[test]
fn chunked_prefill_matches_monolithic_bitwise() {
    // The acceptance property of the session API: a prompt prefilled
    // layer-chunk by layer-chunk — with decode steps of another session
    // interleaved between chunks, exactly as the scheduler does — yields
    // bit-identical hidden states and identical block accounting to the
    // one-shot path.
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-llama",
                                  MethodKind::SharePrefill).unwrap();
    let prompt = latency_prompt(300);

    let mono = engine.prefill(&prompt).unwrap();

    // a second session mid-decode, stepped between the chunks
    let warm = engine.prefill(&latency_prompt(100)).unwrap();
    let mut dec = engine.begin_decode(&warm, 16).unwrap();

    let mut task = engine.begin_prefill(&prompt).unwrap();
    loop {
        let done = engine.prefill_chunk(&mut task, 1).unwrap();
        let _ = engine.decode_step(&mut dec).unwrap();
        if done {
            break;
        }
    }
    let chunked = engine.finish_prefill(task).unwrap();

    assert_eq!(mono.seq, chunked.seq);
    assert_eq!(mono.real_len, chunked.real_len);
    assert_eq!(mono.hidden.as_f32().unwrap(),
               chunked.hidden.as_f32().unwrap(),
               "chunked prefill diverged from monolithic hidden states");
    assert_eq!(mono.stats.blocks_computed, chunked.stats.blocks_computed);
    assert_eq!(mono.stats.blocks_total, chunked.stats.blocks_total);
    assert_eq!((mono.stats.dense, mono.stats.shared, mono.stats.vslash),
               (chunked.stats.dense, chunked.stats.shared,
                chunked.stats.vslash));
    for (l, ((mk, mv), (ck, cv))) in
        mono.kv.iter().zip(chunked.kv.iter()).enumerate() {
        assert_eq!(mk.as_f32().unwrap(), ck.as_f32().unwrap(),
                   "layer {l} K cache diverged");
        assert_eq!(mv.as_f32().unwrap(), cv.as_f32().unwrap(),
                   "layer {l} V cache diverged");
    }
}

#[test]
fn interleaved_multi_prefill_matches_serial_bitwise() {
    // The tentpole property of per-request pattern state: two prompts
    // prefilled with their layer-chunks interleaved on ONE engine (as
    // the multi-prefill scheduler now does) yield hidden states, KV and
    // block accounting bit-identical to prefilling each serially.
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-llama",
                                  MethodKind::SharePrefill).unwrap();
    let prompt_a = latency_prompt(300);
    let prompt_b = sample(Task::EnDia, 5, 200).prompt;

    let serial_a = engine.prefill(&prompt_a).unwrap();
    let serial_b = engine.prefill(&prompt_b).unwrap();

    let mut ta = engine.begin_prefill(&prompt_a).unwrap();
    let mut tb = engine.begin_prefill(&prompt_b).unwrap();
    loop {
        let da = engine.prefill_chunk(&mut ta, 1).unwrap();
        let db = engine.prefill_chunk(&mut tb, 1).unwrap();
        if da && db {
            break;
        }
    }
    let inter_a = engine.finish_prefill(ta).unwrap();
    let inter_b = engine.finish_prefill(tb).unwrap();

    for (name, serial, inter) in [("a", &serial_a, &inter_a),
                                  ("b", &serial_b, &inter_b)] {
        assert_eq!(serial.seq, inter.seq);
        assert_eq!(serial.real_len, inter.real_len);
        assert_eq!(serial.hidden.as_f32().unwrap(),
                   inter.hidden.as_f32().unwrap(),
                   "prompt {name}: interleaved prefill diverged from \
                    serial hidden states");
        assert_eq!(serial.stats.blocks_computed,
                   inter.stats.blocks_computed,
                   "prompt {name}: block accounting diverged");
        assert_eq!((serial.stats.dense, serial.stats.shared,
                    serial.stats.vslash),
                   (inter.stats.dense, inter.stats.shared,
                    inter.stats.vslash),
                   "prompt {name}: pattern decisions diverged");
        for (l, ((sk, sv), (ik, iv))) in
            serial.kv.iter().zip(inter.kv.iter()).enumerate() {
            assert_eq!(sk.as_f32().unwrap(), ik.as_f32().unwrap(),
                       "prompt {name} layer {l} K cache diverged");
            assert_eq!(sv.as_f32().unwrap(), iv.as_f32().unwrap(),
                       "prompt {name} layer {l} V cache diverged");
        }
    }
}

#[test]
fn seq_bucket_padding_preserves_last_logits() {
    // A 200-token prompt runs at the 256 bucket; its last-position logits
    // must not depend on the padding (causality).
    let Some(reg) = registry() else { return };
    let cfg = Config::default();
    let mut engine = build_engine(&reg, &cfg, "sim-llama",
                                  MethodKind::Flash).unwrap();
    let prompt: Vec<i32> = latency_prompt(200);
    let pre = engine.prefill(&prompt).unwrap();
    assert_eq!(pre.seq, 256);
    assert_eq!(pre.real_len, 200);
    let l1 = engine.logits_last(&pre).unwrap();
    // same prompt padded differently by us (append text) -> same logits
    let mut longer = prompt.clone();
    longer.extend_from_slice(&latency_prompt(56));
    let pre2 = engine.prefill(&longer).unwrap();
    let hid = pre2.hidden.as_f32().unwrap();
    let dm = engine.stages.spec.hidden;
    let row = &hid[199 * dm..200 * dm];
    let hid1 = pre.hidden.as_f32().unwrap();
    let row1 = &hid1[199 * dm..200 * dm];
    let err = row.iter().zip(row1)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(err < 1e-4, "padding leaked into causal prefix: {err}");
    let _ = l1;
}
