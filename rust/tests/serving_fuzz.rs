//! Randomized serving fuzz: seeded random interleavings of submit /
//! cancel / scheduling rounds / shutdown-drain over the artifact-free
//! `SimEngine`, across prefill-concurrency levels and adversarial
//! configs (tiny KV pools, tiny budgets, full queues, empty and
//! oversized prompts).
//!
//! Invariants checked on every script:
//!
//! * every submitted session receives **exactly one terminal event**,
//!   and it is the last event on its stream;
//! * no KV blocks leak once the scheduler drains;
//! * the scheduler's request accounting adds up (done + rejected +
//!   cancelled = submitted);
//! * replaying the **identical script with the pattern cache on**
//!   produces a bit-identical event stream (same order, same tokens,
//!   same terminals), and the first-completed (cold) prefill reports
//!   bit-identical block accounting — the cache may only change *warm*
//!   requests' cost, never any request's output;
//! * replaying the **identical script with the prefix-sharing KV
//!   cache on** (`serve.prefix_cache`) also produces a bit-identical
//!   event stream — shared-prefix admissions adopt cached blocks and
//!   skip prefill work, but no session's output may change, no shared
//!   block is ever mutated (the scheduler's insert path is append-only
//!   by construction; the allocator's refcount/COW invariants are
//!   property-tested in `kvcache`), and once the index is flushed the
//!   drained scheduler holds zero KV blocks;
//! * replaying the identical script at a **different worker-pool
//!   width** (1 vs `SHAREPREFILL_WORKERS`, default 4) also produces a
//!   bit-identical event stream — the head-parallel pool may only
//!   change wall-clock, never any request's output;
//! * the same deterministic workload through `spawn_fleet(1, ..)` and
//!   the plain `server::spawn` produces bit-identical per-session
//!   event streams — `serve.shards = 1` *is* the single-engine path;
//! * under **shard-kill fault injection** (shards ∈ {2, 4}), every
//!   session still receives exactly one terminal event, it ends the
//!   stream, the killed shard is restarted, and every shard drains
//!   with zero KV blocks in use at shutdown (no KV leakage).
//!
//! The seed is fixed for reproducibility; override with
//! `SHAREPREFILL_FUZZ_SEED=<u64>` to explore other schedules (CI pins
//! it).  Each suite prints its case count and elapsed time.

use std::collections::HashMap;
use std::time::Instant;

use shareprefill::config::ServeConfig;
use shareprefill::exec::env_workers;
use shareprefill::serving::fleet::spawn_fleet;
use shareprefill::serving::scheduler::Scheduler;
use shareprefill::serving::server;
use shareprefill::serving::sim::SimEngine;
use shareprefill::serving::{Event, EventSink, Request};
use shareprefill::util::rng::Rng;

const LAYERS: usize = 6;
const MAX_PROMPT: usize = 512;

/// The parallel arm of the worker-count dimension (the serial arm is
/// always 1).  The CI matrix sets `SHAREPREFILL_WORKERS` to exercise
/// both pool widths on every push.
fn parallel_workers() -> usize {
    env_workers().unwrap_or(4).max(2)
}

fn fuzz_seed() -> u64 {
    std::env::var("SHAREPREFILL_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_2026)
}

/// One fuzz action.  Scripts are generated up front so the exact same
/// interleaving can be replayed cache-off and cache-on.
#[derive(Debug, Clone)]
enum Op {
    /// Submit a prompt of `len` tokens asking for `max_new` tokens
    /// (len 0 → EmptyPrompt reject; len > MAX_PROMPT → EngineRefused).
    Submit { len: usize, max_new: usize },
    /// Cancel the `nth % submitted` session (may already be terminal —
    /// that must be a no-op, never a second terminal event).
    Cancel { nth: usize },
    /// Run `n` scheduling rounds.
    Rounds(usize),
}

fn gen_script(rng: &mut Rng, ops: usize) -> Vec<Op> {
    (0..ops)
        .map(|_| match rng.below(10) {
            0..=4 => Op::Submit {
                // bias toward valid prompts, keep the edge cases
                len: match rng.below(8) {
                    0 => 0,
                    1 => MAX_PROMPT + 1 + rng.below(128),
                    _ => 1 + rng.below(MAX_PROMPT),
                },
                max_new: rng.below(4),
            },
            5 | 6 => Op::Cancel { nth: rng.below(64) },
            _ => Op::Rounds(1 + rng.below(3)),
        })
        .collect()
}

fn gen_config(rng: &mut Rng, max_prefills: usize) -> ServeConfig {
    ServeConfig {
        max_batch_tokens: *rng.choose(&[1usize, 64, 512, 8192]),
        max_batch_requests: *rng.choose(&[1usize, 2, 8]),
        queue_capacity: *rng.choose(&[1usize, 4, 256]),
        decode_tokens: rng.below(4),
        kv_blocks: *rng.choose(&[8usize, 64, 1024]),
        chunk_layers: 1 + rng.below(3),
        max_concurrent_prefills: max_prefills,
        admit_retries: rng.below(4),
        ..Default::default()
    }
}

/// Adversarial overload config: the serve.admission.* knobs switched
/// on with randomized thresholds, so sheds (queue-depth, kv-headroom,
/// deadline), class priority, and the degradation ladder all fire
/// somewhere in the matrix.
fn gen_admission_config(rng: &mut Rng) -> ServeConfig {
    let mut cfg = ServeConfig {
        max_batch_tokens: *rng.choose(&[64usize, 512]),
        max_batch_requests: *rng.choose(&[2usize, 8]),
        queue_capacity: *rng.choose(&[4usize, 16, 256]),
        decode_tokens: 1 + rng.below(3),
        kv_blocks: *rng.choose(&[64usize, 256, 1024]),
        chunk_layers: 1 + rng.below(2),
        max_concurrent_prefills: 1 + rng.below(3),
        ..Default::default()
    };
    cfg.admission.enabled = true;
    cfg.admission.max_queue_depth = *rng.choose(&[0usize, 2, 8]);
    cfg.admission.kv_overcommit = *rng.choose(&[0.0f64, 1.0, 2.0]);
    cfg.admission.max_queue_rounds = *rng.choose(&[0usize, 4, 32]);
    cfg.admission.interactive_max_tokens = *rng.choose(&[0usize, 32]);
    cfg.admission.degrade_queue_depth = *rng.choose(&[0usize, 3]);
    cfg.admission.degraded_budget_pct = *rng.choose(&[50usize, 100]);
    cfg.admission.degraded_max_prefills = rng.below(2);
    cfg
}

/// Open-loop bursts: volleys of back-to-back submissions (no rounds in
/// between — arrivals don't wait for service) separated by a few
/// scheduling rounds, the arrival shape that drives queues deep enough
/// to make every admission path fire.
fn gen_burst_script(rng: &mut Rng, bursts: usize) -> Vec<Op> {
    let mut script = Vec::new();
    for _ in 0..bursts {
        for _ in 0..4 + rng.below(12) {
            script.push(Op::Submit {
                len: match rng.below(10) {
                    0 => 0,
                    1 => MAX_PROMPT + 1 + rng.below(64),
                    // bias short: interactive-class arrivals dominate
                    _ if rng.below(2) == 0 => 1 + rng.below(32),
                    _ => 1 + rng.below(MAX_PROMPT),
                },
                max_new: rng.below(4),
            });
        }
        if rng.below(4) == 0 {
            script.push(Op::Cancel { nth: rng.below(64) });
        }
        script.push(Op::Rounds(1 + rng.below(4)));
    }
    script
}

/// Order/content signature of an event, excluding timing and prefill
/// stats (which legitimately differ warm vs cold).
fn sig(e: &Event) -> String {
    match e {
        Event::PrefillProgress { id, layers_done, layers_total } => {
            format!("prog:{id}:{layers_done}/{layers_total}")
        }
        Event::PrefillDone { id, .. } => format!("prefill-done:{id}"),
        Event::Token { id, token, index } => {
            format!("tok:{id}:{index}={token}")
        }
        Event::Done { id, response } => {
            format!("done:{id}:{:?}", response.generated)
        }
        Event::Cancelled { id } => format!("cancel:{id}"),
        Event::Rejected { id, reason } => {
            format!("reject:{id}:{}", reason.kind())
        }
        Event::Error { id, .. } => format!("err:{id}"),
    }
}

struct RunOutcome {
    events: Vec<Event>,
    submitted: u64,
}

/// Execute a script against a fresh scheduler + SimEngine, then drain
/// (the shutdown path).  Checks the per-run invariants and returns the
/// globally ordered event stream for cross-run comparison.
fn run_script(script: &[Op], cfg: &ServeConfig, cache_on: bool,
              workers: usize) -> RunOutcome {
    let mut engine = SimEngine::new(LAYERS)
        .with_max_prompt(MAX_PROMPT)
        .with_workers(workers);
    if cache_on {
        engine = engine.with_pattern_cache();
    }
    let mut sched: Scheduler<SimEngine> = Scheduler::new(cfg);
    let (sink, rx) = EventSink::channel();
    let mut next_id = 0u64;
    for op in script {
        match op {
            Op::Submit { len, max_new } => {
                let id = next_id;
                next_id += 1;
                sched.submit(&engine,
                             Request::new(id, vec![1; *len], *max_new),
                             sink.clone());
            }
            Op::Cancel { nth } => {
                if next_id > 0 {
                    sched.cancel((*nth as u64) % next_id);
                }
            }
            Op::Rounds(n) => {
                for _ in 0..*n {
                    sched.run_round(&mut engine).unwrap();
                }
            }
        }
    }
    // shutdown: drain all in-flight work, as the server worker does
    let mut guard = 0;
    while sched.has_work() {
        sched.run_round(&mut engine).unwrap();
        guard += 1;
        assert!(guard < 100_000, "scheduler failed to drain");
    }
    // prefix-cache retains are deliberate state, not a leak: release
    // them before the leak audit (no-op when the knob is off)
    sched.flush_prefix_cache();
    assert_eq!(sched.kv.used(), 0, "kv blocks leaked after drain");
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();

    // exactly one terminal per submitted session, and it ends the stream
    let mut per_id: HashMap<u64, Vec<&Event>> = HashMap::new();
    for e in &events {
        per_id.entry(e.id()).or_default().push(e);
    }
    for id in 0..next_id {
        let evs = per_id.get(&id)
            .unwrap_or_else(|| panic!("session {id}: no events at all"));
        let terminals = evs.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1, "session {id}: {terminals} terminals");
        assert!(evs.last().unwrap().is_terminal(),
                "session {id}: events after its terminal");
    }
    let accounted = sched.metrics.requests_completed
        + sched.metrics.requests_rejected
        + sched.metrics.requests_cancelled
        + sched.metrics.requests_errored;
    assert_eq!(accounted, next_id,
               "request accounting does not add up");
    RunOutcome { events, submitted: next_id }
}

/// Blocks accounting of the chronologically first `PrefillDone` — the
/// first-completed prefill is necessarily cold (nothing was published
/// before it), so cache-on and cache-off must agree bit-for-bit.
fn first_prefill_blocks(events: &[Event])
                        -> Option<(usize, usize, usize)> {
    events.iter().find_map(|e| match e {
        Event::PrefillDone { stats, .. } => Some((
            stats.blocks_computed, stats.blocks_total, stats.cache_hits,
        )),
        _ => None,
    })
}

#[test]
fn fuzz_scheduler_interleavings() {
    let t0 = Instant::now();
    let base = fuzz_seed();
    let mut cases = 0usize;
    let mut sessions = 0u64;
    let par = parallel_workers();
    for &concurrency in &[1usize, 2, 4] {
        for case in 0..6u64 {
            let mut rng =
                Rng::new(base ^ ((concurrency as u64) << 32) ^ case);
            let cfg = gen_config(&mut rng, concurrency);
            let script = gen_script(&mut rng, 40);
            let off = run_script(&script, &cfg, false, 1);
            let on = run_script(&script, &cfg, true, 1);
            // the cache must not change any session's observable output
            let off_sigs: Vec<String> =
                off.events.iter().map(sig).collect();
            let on_sigs: Vec<String> = on.events.iter().map(sig).collect();
            assert_eq!(off_sigs, on_sigs,
                       "cache-on changed the event stream \
                        (concurrency {concurrency}, case {case})");
            // ... and the first (cold) prefill is bit-identical
            let a = first_prefill_blocks(&off.events);
            let b = first_prefill_blocks(&on.events);
            assert_eq!(a, b, "first-request prefill accounting diverged");
            if let Some((_, _, hits)) = b {
                assert_eq!(hits, 0, "first-completed prefill ran warm?");
            }
            // the worker-count dimension: the same script at pool
            // width `par` must produce a bit-identical event stream
            // and bit-identical prefill block accounting — workers
            // may only change wall-clock, never outputs
            let wide = run_script(&script, &cfg, false, par);
            let wide_sigs: Vec<String> =
                wide.events.iter().map(sig).collect();
            assert_eq!(off_sigs, wide_sigs,
                       "workers={par} changed the event stream \
                        (concurrency {concurrency}, case {case})");
            assert_eq!(first_prefill_blocks(&wide.events), a,
                       "workers={par} changed prefill block accounting");
            sessions += off.submitted + wide.submitted;
            cases += 1;
        }
    }
    eprintln!("[fuzz] scheduler interleavings: {cases} cases, \
               {sessions} sessions in {:?}", t0.elapsed());
}

/// Bursty open-loop flood with the admission knobs live, direct
/// scheduler drive plus the threaded fleet front door at shards ∈
/// {1, 2}.  `run_script` asserts the per-run invariants (exactly one
/// terminal per session ending its stream, zero KV blocks after drain,
/// done + rejected + cancelled + errored == submitted); the fleet leg
/// re-checks the terminal-event invariant across threads and parses
/// the aggregate report to reconcile the same accounting identity.
#[test]
fn fuzz_bursty_flood_under_admission_control() {
    let t0 = Instant::now();
    let base = fuzz_seed();
    let mut cases = 0usize;
    let mut shed = 0u64;
    for &shards in &[1usize, 2] {
        for case in 0..3u64 {
            let mut rng =
                Rng::new(base ^ 0xF100D ^ ((shards as u64) << 40) ^ case);
            let cfg = gen_admission_config(&mut rng);
            let script = gen_burst_script(&mut rng, 4);
            // direct drive: the strict invariants live in run_script
            let out = run_script(&script, &cfg, false, 1);
            for e in &out.events {
                if let Event::Rejected { reason, .. } = e {
                    assert!(["queue-full", "empty-prompt", "kv-exhausted",
                             "engine-refused", "queue-depth",
                             "kv-headroom", "deadline"]
                                .contains(&reason.kind()),
                            "unstructured shed reason: {reason:?}");
                    shed += 1;
                }
            }
            // threaded leg: same flood through the fleet front door
            let mut fleet = spawn_fleet(shards, {
                let cfg = cfg.clone();
                move |_| Ok((Scheduler::new(&cfg),
                             SimEngine::new(LAYERS)
                                 .with_max_prompt(MAX_PROMPT)))
            });
            let sessions: Vec<_> = script.iter()
                .filter_map(|op| match op {
                    Op::Submit { len, max_new } => {
                        Some(fleet.submit(vec![1; *len], *max_new))
                    }
                    _ => None,
                })
                .collect();
            let submitted = sessions.len() as u64;
            let report = fleet.shutdown();
            for s in sessions {
                let id = s.id;
                let events = s.collect();
                let last = events.last().unwrap_or_else(
                    || panic!("session {id}: empty stream"));
                assert!(last.is_terminal(),
                        "session {id}: stream ended without a terminal");
                assert_eq!(
                    events.iter().filter(|e| e.is_terminal()).count(), 1,
                    "session {id}: exactly one terminal event");
            }
            // reconcile the aggregate report's requests line
            let line = report.lines()
                .find(|l| l.trim_start().starts_with("requests:"))
                .unwrap_or_else(|| panic!("no requests line: {report}"));
            let counts: Vec<u64> = line
                .split(|c: char| !c.is_ascii_digit())
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            assert_eq!(counts.len(), 4, "bad requests line: {line}");
            assert_eq!(counts.iter().sum::<u64>(), submitted,
                       "shards {shards}, case {case}: report accounting \
                        does not reconcile with {submitted} submissions: \
                        {line}");
            cases += 1;
        }
    }
    assert!(shed > 0, "flood matrix never exercised a structured shed");
    eprintln!("[fuzz] bursty admission flood: {cases} cases, \
               {shed} sheds in {:?}", t0.elapsed());
}

/// The prefix-sharing dimension: the identical script replayed with
/// `serve.prefix_cache` on (random index capacities, eviction
/// included) must be bit-identical to the knob-off run — same event
/// order, same tokens, same terminals, same reject kinds.  All fuzz
/// prompts share token content, so same-length-class submissions are
/// exactly the shared-template workload the cache accelerates; the
/// warm runs must differ only in skipped prefill work.  `run_script`
/// separately asserts zero KV blocks after the index flush, on every
/// run.  A final crafted serial case proves the matrix exercised a
/// genuinely warm admission (nonzero block reuse).
#[test]
fn fuzz_prefix_cache_dimension() {
    let t0 = Instant::now();
    let base = fuzz_seed();
    let mut cases = 0usize;
    let mut reused = 0u64;
    for &concurrency in &[1usize, 2, 4] {
        for case in 0..6u64 {
            let mut rng = Rng::new(
                base ^ 0x70F1 ^ ((concurrency as u64) << 32) ^ case);
            let cfg = gen_config(&mut rng, concurrency);
            let mut warm_cfg = cfg.clone();
            warm_cfg.prefix_cache.enabled = true;
            // tiny capacities force LRU eviction mid-script
            warm_cfg.prefix_cache.capacity =
                *rng.choose(&[1usize, 4, 512]);
            let script = gen_script(&mut rng, 40);
            let off = run_script(&script, &cfg, false, 1);
            let on = run_script(&script, &warm_cfg, false, 1);
            let off_sigs: Vec<String> =
                off.events.iter().map(sig).collect();
            let on_sigs: Vec<String> =
                on.events.iter().map(sig).collect();
            assert_eq!(off_sigs, on_sigs,
                       "prefix cache changed the event stream \
                        (concurrency {concurrency}, case {case})");
            for e in &on.events {
                if let Event::PrefillDone { stats, .. } = e {
                    reused += stats.prefix_blocks_reused as u64;
                }
            }
            cases += 1;
        }
    }
    // crafted warm case: a completed 256-token prompt republishes its
    // chunks, so an identical follow-up must adopt them — guarantees
    // the reuse counter below cannot be satisfied vacuously
    let mut warm_cfg = ServeConfig::default();
    warm_cfg.prefix_cache.enabled = true;
    let script = vec![
        Op::Submit { len: 256, max_new: 1 },
        Op::Rounds(64),
        Op::Submit { len: 256, max_new: 1 },
    ];
    let out = run_script(&script, &warm_cfg, false, 1);
    for e in &out.events {
        if let Event::PrefillDone { stats, .. } = e {
            reused += stats.prefix_blocks_reused as u64;
        }
    }
    assert!(reused > 0,
            "prefix matrix never exercised a warm admission");
    eprintln!("[fuzz] prefix-cache dimension: {cases} cases, {reused} \
               blocks reused in {:?}", t0.elapsed());
}

/// Thread-level fuzz over the server front-end: random submit / cancel
/// traffic, then `shutdown` — every session stream must end in exactly
/// one terminal event and the report must come back.
#[test]
fn fuzz_server_submit_cancel_shutdown() {
    let t0 = Instant::now();
    let mut rng = Rng::new(fuzz_seed() ^ 0xA5A5_A5A5);
    let cases = 8usize;
    for case in 0..cases {
        let cfg = ServeConfig {
            max_batch_tokens: *rng.choose(&[32usize, 256]),
            decode_tokens: 1 + rng.below(4),
            chunk_layers: 1,
            max_concurrent_prefills: 1 + rng.below(3),
            ..Default::default()
        };
        let cache_on = case % 2 == 0;
        // alternate pool widths so the thread-level fuzz exercises the
        // parallel engine path too
        let workers = if case % 2 == 0 { 1 } else { parallel_workers() };
        let handle = server::spawn(move || {
            // deep layer stack: prefills span many rounds, so cancels
            // land mid-flight
            let engine = SimEngine::new(32).with_workers(workers);
            let engine = if cache_on {
                engine.with_pattern_cache()
            } else {
                engine
            };
            Ok((Scheduler::new(&cfg), engine))
        });
        let n = 3 + rng.below(6);
        let sessions: Vec<_> = (0..n)
            .map(|_| {
                handle.submit(vec![1; 32 + rng.below(256)],
                              1 + rng.below(4))
            })
            .collect();
        for s in &sessions {
            if rng.below(4) == 0 {
                handle.cancel(s.id);
            }
        }
        let report = handle.shutdown();
        assert!(report.contains("requests:"),
                "case {case}: bad report: {report}");
        for s in sessions {
            let id = s.id;
            let events = s.collect();
            let last = events.last()
                .unwrap_or_else(|| panic!("session {id}: empty stream"));
            assert!(last.is_terminal(),
                    "session {id}: stream ended without a terminal");
            assert_eq!(events.iter().filter(|e| e.is_terminal()).count(),
                       1, "session {id}: exactly one terminal event");
        }
    }
    eprintln!("[fuzz] server lifecycle: {cases} cases in {:?}",
              t0.elapsed());
}

/// `serve.shards = 1` bit-identity at the fuzz level: the same
/// deterministic workload (no cancels — a cancel's landing round is
/// timing-dependent, which would make the comparison flaky rather than
/// prove anything) through the pre-fleet `server::spawn` and a 1-shard
/// fleet must yield identical per-session event streams, edge cases
/// (empty and oversized prompts) included.
#[test]
fn fuzz_fleet_single_shard_is_bit_identical_to_server() {
    let t0 = Instant::now();
    let mut rng = Rng::new(fuzz_seed() ^ 0x00F1_EE70);
    let cases = 4usize;
    for case in 0..cases {
        let cfg = ServeConfig {
            max_batch_tokens: *rng.choose(&[64usize, 8192]),
            decode_tokens: 1 + rng.below(3),
            chunk_layers: 1 + rng.below(3),
            max_concurrent_prefills: 1 + rng.below(3),
            ..Default::default()
        };
        let workload: Vec<(usize, usize)> = (0..4 + rng.below(6))
            .map(|_| {
                let len = match rng.below(8) {
                    0 => 0,
                    1 => MAX_PROMPT + 1 + rng.below(64),
                    _ => 1 + rng.below(MAX_PROMPT),
                };
                (len, 1 + rng.below(3))
            })
            .collect();
        let server = server::spawn({
            let cfg = cfg.clone();
            move || Ok((Scheduler::new(&cfg),
                        SimEngine::new(LAYERS).with_max_prompt(MAX_PROMPT)))
        });
        let mut fleet = spawn_fleet(1, {
            let cfg = cfg.clone();
            move |_| Ok((Scheduler::new(&cfg),
                         SimEngine::new(LAYERS)
                             .with_max_prompt(MAX_PROMPT)))
        });
        assert!(fleet.is_single(),
                "shards=1 must be the plain server path");
        let on_server: Vec<_> = workload.iter()
            .map(|&(len, max_new)| server.submit(vec![1; len], max_new))
            .collect();
        let on_fleet: Vec<_> = workload.iter()
            .map(|&(len, max_new)| fleet.submit(vec![1; len], max_new))
            .collect();
        for (a, b) in on_server.into_iter().zip(on_fleet) {
            let sa: Vec<String> = a.collect().iter().map(sig).collect();
            let sb: Vec<String> = b.collect().iter().map(sig).collect();
            assert_eq!(sa, sb,
                       "case {case}: shards=1 diverged from the server");
        }
        let ra = server.shutdown();
        let rb = fleet.shutdown();
        assert_eq!(ra.lines().next(), rb.lines().next(),
                   "case {case}: request accounting diverged");
        assert!(!rb.contains("fleet:"),
                "case {case}: single path grew a fleet summary");
    }
    eprintln!("[fuzz] fleet single-shard parity: {cases} cases in {:?}",
              t0.elapsed());
}

/// Shard-kill fault injection at shards ∈ {2, 4}: random traffic
/// (with cancels) over slow simulated engines, one shard killed
/// mid-flight.  Every session must still get exactly one terminal
/// event ending its stream; the supervisor must restart the shard; and
/// at shutdown every shard must drain with zero KV blocks in use (the
/// per-shard clean-exit flag the fleet summary counts) — the KV-leak
/// invariant across failure and restart.
#[test]
fn fuzz_fleet_shard_kill_invariants() {
    let t0 = Instant::now();
    let mut rng = Rng::new(fuzz_seed() ^ 0x0051_AB00);
    let mut cases = 0usize;
    for &shards in &[2usize, 4] {
        for case in 0..3u64 {
            let cache_on = case % 2 == 0;
            let cfg = ServeConfig::default();
            let mut fleet = spawn_fleet(shards, {
                let cfg = cfg.clone();
                move |_| {
                    // slow prefills so the kill lands mid-flight
                    let mut e = SimEngine::new(LAYERS)
                        .with_max_prompt(MAX_PROMPT)
                        .with_work(10_000);
                    if cache_on {
                        e = e.with_pattern_cache();
                    }
                    Ok((Scheduler::new(&cfg), e))
                }
            });
            assert_eq!(fleet.shard_count(), shards);
            let n = 4 + rng.below(8);
            let sessions: Vec<_> = (0..n)
                .map(|_| fleet.submit(
                    vec![1; 64 + rng.below(MAX_PROMPT - 64)],
                    1 + rng.below(3)))
                .collect();
            for s in &sessions {
                if rng.below(5) == 0 {
                    fleet.cancel(s.id);
                }
            }
            fleet.kill_shard(rng.below(shards));
            // drive the supervision pump until the crash is observed
            // and repaired (terminal Errors synthesized, shard respawned)
            for _ in 0..10_000 {
                fleet.pump_now();
                if fleet.restarts() >= 1 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            assert!(fleet.restarts() >= 1,
                    "supervisor never observed the kill \
                     (shards {shards}, case {case})");
            for s in sessions {
                let id = s.id;
                let events = s.collect();
                let last = events.last().unwrap_or_else(
                    || panic!("session {id}: empty stream"));
                assert!(last.is_terminal(),
                        "session {id}: stream ended without a terminal");
                assert_eq!(
                    events.iter().filter(|e| e.is_terminal()).count(), 1,
                    "session {id}: exactly one terminal event");
            }
            let report = fleet.shutdown();
            assert!(report.contains(&format!("fleet: {shards} shards")),
                    "missing fleet summary: {report}");
            assert!(report.contains("0 unclean exits"),
                    "KV leaked across failure/restart \
                     (shards {shards}, case {case}): {report}");
            cases += 1;
        }
    }
    eprintln!("[fuzz] fleet shard-kill: {cases} cases in {:?}",
              t0.elapsed());
}
