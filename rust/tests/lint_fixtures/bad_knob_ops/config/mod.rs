// lint fixture: serve.workers is wired to the CLI and the design doc,
// but the operator's handbook the test passes has no row for it.
pub fn apply(t: &Toml, c: &mut Cfg) {
    c.workers = t.usize_or("serve.workers", c.workers);
}
