// lint fixture: the CLI flag backing the knob in config/mod.rs.
pub const USAGE: &str = "serve [--workers N]";
