// lint fixture: only the workers knob is wired up here.  (Careful:
// the flag lookup scans raw text, so this comment must not name the
// missing flag.)
pub const USAGE: &str = "serve [--workers N]";
