// lint fixture: serve.magic_level has neither a CLI flag nor a
// design-doc entry; serve.workers is wired correctly for contrast.
pub fn apply(t: &Toml, c: &mut Cfg) {
    c.workers = t.usize_or("serve.workers", c.workers);
    c.magic = t.usize_or("serve.magic_level", c.magic);
}
