// lint fixture: order-bearing state inside a fan_out closure.
pub fn plan(pool: &Pool, state: &Shared, n: usize) -> Vec<u32> {
    pool.fan_out(n, |h| {
        state.inner.borrow_mut().decide_pattern(h)
    })
}
