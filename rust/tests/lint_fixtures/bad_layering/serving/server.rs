// lint fixture: serving importing a harness and spawning raw threads.
use crate::eval::open_registry;

pub fn start() {
    std::thread::spawn(|| run());
}
