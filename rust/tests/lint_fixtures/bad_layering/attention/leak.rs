// lint fixture: the pattern engine reaching up into serving.
use crate::serving::Scheduler;

pub fn peek(s: &Scheduler) -> usize {
    s.depth()
}
