// lint fixture: a raw unwrap in the thresholded-discovery method,
// which sits inside the panic-hygiene hot-path scope like the other
// methods/ hot-path files.
pub fn plan(budget: Option<usize>) -> usize {
    budget.unwrap()
}
