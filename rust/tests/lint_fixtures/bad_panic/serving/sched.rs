// lint fixture: raw panic sites in the hot path — an unwrap and an
// expect whose message is not an "invariant: ..." contract.
pub fn pop(q: &mut Vec<u32>) -> u32 {
    q.pop().unwrap()
}

pub fn head(q: &[u32]) -> u32 {
    q.first().copied().expect("queue is non-empty")
}
