// lint fixture: a pure per-head fan-out closure — nothing in the
// argument span carries order-bearing state.
pub fn masks(pool: &Pool, n: usize) -> Vec<u32> {
    pool.fan_out(n, |h| search(h))
}
