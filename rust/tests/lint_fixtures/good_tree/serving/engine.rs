// lint fixture: the one sanctioned panic form in the hot path — an
// expect whose message documents the invariant.
pub fn take(x: Option<u32>) -> u32 {
    x.expect("invariant: populated by the caller")
}
