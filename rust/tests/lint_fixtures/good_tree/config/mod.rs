// lint fixture: a serve knob that is wired end to end — parsed here,
// `--workers` in cli_main.rs, named in the design doc the test passes.
pub fn apply(t: &Toml, c: &mut Cfg) {
    c.workers = t.usize_or("serve.workers", c.workers);
}
