// lint fixture: `exec` is the sanctioned thread owner, so naming
// std::thread here is allowed by the layering rule.
pub fn spawn() {
    std::thread::spawn(|| {}).join().ok();
}
