//! Self-tests for pallas-lint: fixture trees under
//! `rust/tests/lint_fixtures/` (one clean tree plus one violating
//! tree per rule, asserted down to exact file/line/rule), the binary's
//! exit codes, and the load-bearing gate — the shipped sources must be
//! lint-clean against the committed `lint_baseline.toml` and DESIGN.md,
//! so `cargo test` fails the moment the tree and the baseline drift.

use std::path::{Path, PathBuf};
use std::process::Command;

use shareprefill::lint::{self, baseline, rules};

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures")
}

fn check(tree: &str, base: &baseline::Baseline, design: Option<&str>)
         -> Vec<lint::Diagnostic> {
    check_ops(tree, base, design, None)
}

fn check_ops(tree: &str, base: &baseline::Baseline, design: Option<&str>,
             ops: Option<&str>) -> Vec<lint::Diagnostic> {
    lint::check_tree(&fixtures().join(tree), Some(base), design, ops)
        .expect("fixture tree must be walkable")
        .diagnostics
}

fn empty() -> baseline::Baseline {
    baseline::Baseline::default()
}

fn keys(diags: &[lint::Diagnostic]) -> Vec<(String, usize, &str)> {
    diags.iter().map(|d| (d.file.clone(), d.line, d.rule)).collect()
}

#[test]
fn good_tree_is_clean() {
    let design = "knob table: serve.workers maps to --workers";
    let ops = "| serve.workers | --workers | 1 | more prefill threads |";
    let diags = check_ops("good_tree", &empty(), Some(design), Some(ops));
    assert!(diags.is_empty(), "unexpected findings: {diags:?}");
}

#[test]
fn bad_layering_exact_diagnostics() {
    let diags = check("bad_layering", &empty(), None);
    assert_eq!(keys(&diags), vec![
        ("attention/leak.rs".to_string(), 2, rules::RULE_LAYERING),
        ("serving/server.rs".to_string(), 2, rules::RULE_LAYERING),
        ("serving/server.rs".to_string(), 5, rules::RULE_LAYERING),
    ]);
    assert!(diags[0].message.contains("may not import `serving`"));
    assert!(diags[1].message.contains("`eval`"));
    assert!(diags[2].message.contains("std::thread"));
}

#[test]
fn bad_determinism_exact_diagnostics() {
    let diags = check("bad_determinism", &empty(), None);
    assert_eq!(keys(&diags), vec![
        ("attention/par.rs".to_string(), 4, rules::RULE_DETERMINISM),
        ("attention/par.rs".to_string(), 4, rules::RULE_DETERMINISM),
    ]);
    assert!(diags[0].message.contains("borrow_mut"),
            "offset order: borrow_mut first on the line");
    assert!(diags[1].message.contains("decide_pattern"));
}

#[test]
fn bad_panic_flags_new_sites() {
    let diags = check("bad_panic", &empty(), None);
    // BTreeMap order: methods/ sorts before serving/
    assert_eq!(keys(&diags), vec![
        ("methods/flash_threshold.rs".to_string(), 5, rules::RULE_PANIC),
        ("serving/sched.rs".to_string(), 4, rules::RULE_PANIC),
        ("serving/sched.rs".to_string(), 8, rules::RULE_PANIC),
    ]);
    assert!(diags[0].message.contains("`unwrap()`"));
    assert!(diags[0].message.contains("baseline allows 0"));
    assert!(diags[1].message.contains("`unwrap()`"));
    assert!(diags[2].message.contains("`expect(..)`"));
}

#[test]
fn baseline_freezes_and_ratchets() {
    // exact freeze: no findings
    let frozen = baseline::parse(
        "\"methods/flash_threshold.rs\" = 1\n\"serving/sched.rs\" = 2\n")
        .unwrap();
    assert!(check("bad_panic", &frozen, None).is_empty());

    // baseline above reality: the shrink must be recorded
    let loose = baseline::parse(
        "\"methods/flash_threshold.rs\" = 1\n\"serving/sched.rs\" = 5\n")
        .unwrap();
    let diags = check("bad_panic", &loose, None);
    assert_eq!(keys(&diags),
               vec![("serving/sched.rs".to_string(), 1,
                     rules::RULE_PANIC)]);
    assert!(diags[0].message.contains("stale baseline"));

    // baseline entry for a file with no sites at all: same ratchet
    let ghost = baseline::parse(
        "\"methods/flash_threshold.rs\" = 1\n\"serving/gone.rs\" = 1\n\
         \"serving/sched.rs\" = 2\n").unwrap();
    let diags = check("bad_panic", &ghost, None);
    assert_eq!(keys(&diags),
               vec![("serving/gone.rs".to_string(), 1,
                     rules::RULE_PANIC)]);
    assert!(diags[0].message.contains("stale baseline"));
}

#[test]
fn bad_knobs_exact_diagnostics() {
    let design = "documented knobs: serve.workers only";
    let diags = check("bad_knobs", &empty(), Some(design));
    assert_eq!(keys(&diags), vec![
        ("config/mod.rs".to_string(), 5, rules::RULE_KNOBS),
        ("config/mod.rs".to_string(), 5, rules::RULE_KNOBS),
    ]);
    assert!(diags[0].message.contains("--magic-level"),
            "flag half first: {}", diags[0].message);
    assert!(diags[1].message.contains("DESIGN.md"));
}

#[test]
fn bad_knob_ops_exact_diagnostics() {
    // serve.workers is wired to the CLI and named in the design doc,
    // but the operator's handbook has no row for it: exactly the one
    // new diagnostic, anchored on the key's parse site
    let design = "knob table: serve.workers maps to --workers";
    let diags = check_ops("bad_knob_ops", &empty(), Some(design),
                          Some("operator handbook with no knob table"));
    assert_eq!(keys(&diags), vec![
        ("config/mod.rs".to_string(), 4, rules::RULE_KNOBS),
    ]);
    assert!(diags[0].message.contains("OPERATIONS.md"),
            "handbook half: {}", diags[0].message);
    // with the row present the tree is clean again
    let ops = "| serve.workers | --workers | 1 | prefill threads |";
    assert!(check_ops("bad_knob_ops", &empty(), Some(design), Some(ops))
                .is_empty());
    // and ops = None (no handbook shipped) skips the half entirely
    assert!(check("bad_knob_ops", &empty(), Some(design)).is_empty());
}

#[test]
fn write_baseline_counts_match_found_sites() {
    // base = None is the --write-baseline path: no ratchet comparison,
    // panic_counts carries what would be frozen
    let report = lint::check_tree(&fixtures().join("bad_panic"),
                                  None, None, None).unwrap();
    assert!(report.diagnostics.is_empty(),
            "write mode must not emit ratchet findings");
    assert_eq!(report.panic_counts.get("serving/sched.rs"), Some(&2));
    assert_eq!(report.panic_counts.get("methods/flash_threshold.rs"),
               Some(&1));
    let b = baseline::parse(&baseline::render(&report.panic_counts))
        .unwrap();
    assert_eq!(b.allowed("serving/sched.rs"), 2);
    assert_eq!(b.allowed("methods/flash_threshold.rs"), 1);
}

#[test]
fn diagnostic_render_format() {
    let diags = check("bad_layering", &empty(), None);
    let line = diags[0].to_string();
    assert!(line.starts_with("attention/leak.rs:2: [layering] "),
            "rendered: {line}");
}

#[test]
fn binary_exit_codes() {
    let bin = env!("CARGO_BIN_EXE_pallas-lint");
    // cwd = the fixtures dir: no lint_baseline.toml / DESIGN.md there,
    // so the binary's defaults are skipped and fixtures stand alone
    let run = |tree: &str| {
        Command::new(bin)
            .args(["--check", tree])
            .current_dir(fixtures())
            .output()
            .expect("pallas-lint binary runs")
    };

    let good = run("good_tree");
    assert_eq!(good.status.code(), Some(0), "good tree is clean");
    let stdout = String::from_utf8_lossy(&good.stdout);
    assert!(stdout.contains("pallas-lint: clean (5 file(s) checked)"),
            "stdout: {stdout}");

    let bad = run("bad_layering");
    assert_eq!(bad.status.code(), Some(1), "findings exit 1");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("attention/leak.rs:2: [layering]"),
            "stdout: {stdout}");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("3 finding(s)"), "stderr: {stderr}");

    let missing = run("no_such_tree");
    assert_eq!(missing.status.code(), Some(2), "usage/IO error exit 2");
}

/// The gate: the shipped tree itself must be clean against the
/// committed baseline and DESIGN.md.  This is what keeps the Rust
/// scanner and `tools/lint_baseline_gen.py` honest about each other —
/// the committed `lint_baseline.toml` was generated by the Python
/// replica, and this test replays it through the Rust implementation.
#[test]
fn shipped_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let base = baseline::load(&root.join("lint_baseline.toml"))
        .expect("committed baseline parses");
    let design = std::fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md is readable");
    let ops = std::fs::read_to_string(root.join("docs/OPERATIONS.md"))
        .expect("docs/OPERATIONS.md is readable");
    let report = lint::check_tree(&root.join("rust/src"), Some(&base),
                                  Some(&design), Some(&ops)).unwrap();
    for d in &report.diagnostics {
        eprintln!("{d}");
    }
    assert!(report.diagnostics.is_empty(),
            "pallas-lint findings on the shipped tree — run `cargo run \
             --bin pallas-lint -- --check rust/src` for details");
    assert!(report.files > 40,
            "walker saw only {} files — wrong root?", report.files);
}
